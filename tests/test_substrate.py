"""Substrate: data pipeline determinism/sharding, checkpoint save/restore/
reshard, optimizer + gradient compression, fault tolerance."""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import latest_step, restore, save, save_async
from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticPipeline
from repro.optim import OptConfig, adamw_update, init_opt_state, lr_at
from repro.optim.compress import (compress_decompress, init_state as comp_init,
                                  wire_bytes)
from repro.runtime.fault_tolerance import (ClusterState, HeartbeatMonitor,
                                           MeshPlan, StragglerMitigator,
                                           plan_mesh, resharding_moves)

CFG = reduced(get_config("deepseek-7b"))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    pipe = SyntheticPipeline(CFG, DataConfig(seq_len=32, global_batch=4))
    b1 = pipe.batch_at(7)
    b2 = pipe.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = pipe.iter_from(7)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full = pipe.batch_at(3)
    assert full["tokens"].shape == (4, 32)


def test_pipeline_host_sharding_disjoint():
    dcs = [DataConfig(seq_len=16, global_batch=8, n_hosts=2, host_id=h)
           for h in (0, 1)]
    b0 = SyntheticPipeline(CFG, dcs[0]).batch_at(0)
    b1 = SyntheticPipeline(CFG, dcs[1]).batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tiny_tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": (jnp.ones((2,)), jnp.zeros((3,), jnp.bfloat16))}}


def test_ckpt_roundtrip(tmp_path):
    tree = _tiny_tree()
    save(str(tmp_path), 5, tree, extra={"step": 5})
    assert latest_step(str(tmp_path)) == 5
    got, extra = restore(str(tmp_path), 5, tree)
    assert extra["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_keep_last_and_async(tmp_path):
    tree = _tiny_tree()
    threads = [save_async(str(tmp_path), s, tree, keep_last=2) for s in (1, 2, 3)]
    for t in threads:
        t.join()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps[-1] == 3 and len(steps) <= 2


def test_ckpt_shape_mismatch_detected(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jnp.ones((5,))})


def test_ckpt_resume_training_continues_identically(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    from repro.train import make_train_step
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    pipe = SyntheticPipeline(CFG, DataConfig(seq_len=32, global_batch=2))
    step_fn = jax.jit(make_train_step(CFG, oc))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, m = step_fn(params, opt, batch)
        return params, opt, m

    from repro.models import init_params
    p0 = init_params(CFG, jax.random.key(0))
    o0 = init_opt_state(p0)
    pA, oA, mA = run(p0, o0, 0, 6)

    p1 = init_params(CFG, jax.random.key(0))
    o1 = init_opt_state(p1)
    p1, o1, _ = run(p1, o1, 0, 3)
    save(str(tmp_path), 3, (p1, o1), extra={"step": 3})
    (p2, o2), extra = restore(str(tmp_path), 3, (p1, o1))
    pB, oB, mB = run(p2, o2, extra["step"], 6)
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# Optimizer + compression
# ---------------------------------------------------------------------------


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(oc, 0)) < 0.11
    assert abs(float(lr_at(oc, 10)) - 1.0) < 1e-6
    assert float(lr_at(oc, 100)) == pytest.approx(0.1, rel=1e-3)


def test_adamw_reduces_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0,
                   grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(oc, params, g, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compressed_grads_converge_with_error_feedback(scheme):
    oc = OptConfig(lr=0.05, warmup_steps=0, total_steps=400, weight_decay=0.0,
                   grad_clip=10.0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)),
                               jnp.float32)}
    opt = init_opt_state(params)
    ef = comp_init(params)
    for _ in range(400):
        g = {"w": 2 * params["w"]}
        g, ef = compress_decompress(g, ef, scheme, topk_frac=0.1)
        params, opt, _ = adamw_update(oc, params, g, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_compression_wire_bytes():
    g = {"w": jnp.zeros((1000,), jnp.bfloat16)}
    assert wire_bytes(g, "none") == 2000
    assert wire_bytes(g, "int8") == 1000
    assert wire_bytes(g, "topk", 0.05) == pytest.approx(400)


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_failure_detection():
    m = HeartbeatMonitor(["a", "b", "c"], timeout_s=10)
    for w in ("a", "b", "c"):
        m.beat(w, 0.0)
    m.beat("a", 20.0)
    m.beat("b", 20.0)
    assert m.failed(25.0) == ["c"]
    assert m.alive(25.0) == ["a", "b"]


@given(st.integers(min_value=1, max_value=4096))
@settings(max_examples=100, deadline=None)
def test_plan_mesh_properties(chips):
    plan = plan_mesh(chips)
    assert plan.n_chips + plan.dropped_chips == chips
    assert plan.n_chips >= 1
    n = 1
    for s in plan.shape:
        n *= s
    assert n == plan.n_chips
    assert len(plan.shape) == len(plan.axes)


def test_plan_mesh_keeps_tp_axis_when_possible():
    assert plan_mesh(256).shape == (16, 16)
    assert plan_mesh(512).shape == (2, 16, 16)
    assert plan_mesh(250).shape == (15, 16)  # drop 10 chips, keep TP=16
    assert plan_mesh(8).shape[-1] == 8


def test_resharding_moves():
    old = plan_mesh(256)
    new = plan_mesh(240)
    mv = resharding_moves(old, new, 1e9)
    assert mv["kind"] == "dp_relayout" and not mv["ckpt_reload"]
    tiny = plan_mesh(8)
    mv2 = resharding_moves(old, tiny, 1e9)
    assert mv2["ckpt_reload"]


def test_straggler_eviction():
    sm = StragglerMitigator(["a", "b", "c", "d"])
    for _ in range(5):
        evict = sm.record_step({"a": 1.0, "b": 1.0, "c": 1.0, "d": 5.0})
    assert evict == ["d"]


def test_cluster_state_replans_on_failure():
    cs = ClusterState(workers=[f"w{i}" for i in range(64)], chips_per_worker=4)
    now = 0.0
    for w in cs.workers:
        cs.monitor.beat(w, now)
    plan = cs.current_plan(now)
    assert plan.n_chips == 256
    # w0 stops heartbeating
    now = 100.0
    for w in cs.workers[1:]:
        cs.monitor.beat(w, now)
    new_plan = cs.handle_step(now, {w: 1.0 for w in cs.workers[1:]})
    assert new_plan is not None and new_plan.n_chips == 252 // 16 * 16
