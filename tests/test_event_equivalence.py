"""Compat-driver regression (DESIGN.md §12): the lockstep clock is the
default, so every pre-event-core sweep must keep reproducing its gated
metrics unchanged — the benchmark sweeps run here downscaled, with their
internal gates (pressure-ledger balance, no silent drops, fleet prefill
cut, decode equivalence) still armed.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.serving import ClusterFrontend


def test_lockstep_is_the_default_clock():
    """Existing callers constructed ClusterFrontend without a clock_mode;
    the compat guarantee is that they still get lockstep semantics."""
    import inspect
    sig = inspect.signature(ClusterFrontend.__init__)
    assert sig.parameters["clock_mode"].default == "lockstep"
    with pytest.raises(ValueError, match="clock_mode"):
        # clock_mode is validated before the engine list is touched
        ClusterFrontend([object()], clock_mode="warp")


def test_cluster_sweep_reproduces_gated_metrics():
    """The PR 3 capacity-pressure replica sweep, downscaled. Its internal
    gates assert the pressure ledger balances, nothing was silently
    dropped, and fleet tokens equal the per-replica sum."""
    from benchmarks.serving_sim import cluster_sweep
    out = cluster_sweep(replica_counts=(2,), requests=6)
    row = out["replicas_2"]
    assert row["finished"] == 6
    assert row["pressure_events"] > 0
    assert row["pressure_events"] == row["pressure_resolved"]
    assert row["dropped_allocs"] == 0
    assert row["tokens_generated"] > 0
    assert row["ttft_p50_s"] > 0


def test_fleet_reuse_sweep_reproduces_gated_metrics():
    """The PR 7 fleet-migration A/B, downscaled. Its internal gates
    assert decode equivalence between the fleet and per-replica arms,
    ledger balance, real migrations, and a >=20% fleet prefill cut."""
    from benchmarks.serving_sim import fleet_reuse
    out = fleet_reuse(replicas=2, fanout=6)
    assert out["ledger_imbalance"] == 0
    assert out["migrations"] > 0 and out["cross_replica_hits"] > 0
    assert out["prefill_cut"] >= 0.20
    assert out["dropped_allocs"] == 0
