"""MRM core: DCM trade-off monotonicity (hypothesis property tests),
Figure-1 endurance arithmetic, wear-levelling allocator invariants,
retention-aware ECC, tiering solver, refresh scheduler, simulator."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (Action, DataClassProfile, MemorySystem, RefreshScheduler,
                        RetentionTracker, Tier, WearLevelingAllocator, WearState,
                        design_code, endurance_at, evaluate_placement, max_safe_age,
                        plan_write, rber_at_age, solve_placement,
                        weight_update_writes, write_energy, writes_per_cell)
from repro.core.memclass import (DAY, HOUR, YEAR, HBM3E, LPDDR5X, MRM_MRAM,
                                 MRM_PCM, MRM_RRAM, NAND_SLC, OPTANE_PCM,
                                 RRAM_DEVICE, STT_MRAM_DEVICE, TECHNOLOGIES,
                                 get_technology)

MANAGED = [MRM_PCM, MRM_RRAM, MRM_MRAM]


# ---------------------------------------------------------------------------
# DCM
# ---------------------------------------------------------------------------


@given(st.floats(min_value=1.0, max_value=2 * DAY),
       st.floats(min_value=1.0, max_value=2 * DAY))
@settings(max_examples=50, deadline=None)
def test_dcm_write_energy_monotone_in_retention(r1, r2):
    for tech in MANAGED:
        e1, e2 = write_energy(tech, r1), write_energy(tech, r2)
        if r1 <= r2:
            assert e1 <= e2 + 1e-9
        assert 0 < e1 <= tech.write_energy_pj_bit + 1e-9


@given(st.floats(min_value=1.0, max_value=2 * DAY))
@settings(max_examples=50, deadline=None)
def test_dcm_endurance_gains_never_exceed_potential(r):
    for tech in MANAGED:
        e = endurance_at(tech, r)
        assert tech.endurance_device - 1 <= e <= tech.endurance_potential + 1


def test_dcm_plan_write_relaxation_pays():
    """Shorter-lived data must be cheaper to write and wear less."""
    for tech in MANAGED:
        short = plan_write(tech, 60.0)
        long_ = plan_write(tech, tech.retention_s)
        assert short.energy_pj_bit <= long_.energy_pj_bit
        assert short.endurance_at_point >= long_.endurance_at_point


# ---------------------------------------------------------------------------
# Figure-1 arithmetic (paper §3)
# ---------------------------------------------------------------------------


def test_fig1_weight_update_endurance_requirements():
    hourly = weight_update_writes(HOUR)
    per_second = weight_update_writes(1.0)
    assert 4e4 < hourly < 5e4          # ~4.4e4 writes over 5 years
    assert 1.4e8 < per_second < 1.7e8  # ~1.58e8


def test_fig1_kv_cache_endurance_requirement():
    """Splitwise llama2-70b-ish: prefill ~7k tok/s/machine, 0.33 MB/token,
    KV region of several hundred GB -> 1e5..1e7 writes/cell over 5 years."""
    from repro.configs import get_config
    kv_per_tok = get_config("llama2-70b").kv_bytes_per_token()
    wpc = writes_per_cell(7000 * kv_per_tok, 400e9)
    assert 1e5 < wpc < 1e7


def test_fig1_technology_ordering():
    """The paper's Fig-1 qualitative claims: Flash SLC insufficient for KV;
    current SCM devices don't meet the requirements (PCM/RRAM fail the
    once-per-second weight-update bar; RRAM also the worst-levelled KV bar);
    technology potentials sufficient; DRAM/HBM vastly overprovisioned."""
    from repro.configs import get_config
    kv_per_tok = get_config("llama2-70b").kv_bytes_per_token()
    kv_req = writes_per_cell(7000 * kv_per_tok, 400e9)
    kv_req_worst = writes_per_cell(7000 * kv_per_tok, 400e9,
                                   leveling_efficiency=0.5)
    w_sec = weight_update_writes(1.0)
    assert NAND_SLC.endurance_device < kv_req
    assert RRAM_DEVICE.endurance_device < kv_req_worst
    assert OPTANE_PCM.endurance_device < w_sec
    assert RRAM_DEVICE.endurance_device < w_sec
    for t in (OPTANE_PCM, RRAM_DEVICE, STT_MRAM_DEVICE):
        assert t.endurance_potential > max(kv_req_worst, w_sec)
    assert HBM3E.endurance_device > 1e4 * max(kv_req, w_sec)


# ---------------------------------------------------------------------------
# Wear levelling
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(1, 12), st.booleans()), min_size=1,
                max_size=60))
@settings(max_examples=40, deadline=None)
def test_allocator_never_double_allocates(ops):
    wear = WearState(n_blocks=64, block_bytes=4096, endurance=1e9)
    alloc = WearLevelingAllocator(wear)
    live = []
    allocated_now = set()
    for n, do_free in ops:
        got = alloc.alloc(n)
        if got is not None:
            assert not (set(got) & allocated_now), "double allocation!"
            allocated_now.update(got)
            live.append(got)
        if do_free and live:
            blocks = live.pop(0)
            alloc.free_blocks(blocks)
            allocated_now.difference_update(blocks)
    assert 0.0 <= alloc.utilization <= 1.0


def test_allocator_prefers_least_worn():
    wear = WearState(n_blocks=8, block_bytes=64, endurance=1e9)
    alloc = WearLevelingAllocator(wear)
    a = alloc.alloc(8)
    alloc.rewrite_in_place(a[:4])  # wear blocks 0..3 extra
    alloc.free_blocks(a)
    b = alloc.alloc(4)
    assert set(b) == {4, 5, 6, 7}  # least-worn reused first


def test_wear_lifetime_projection():
    wear = WearState(n_blocks=4, block_bytes=100, endurance=1000)
    wear.record_write([0, 1, 2, 3])
    t = wear.project_lifetime_s(write_bytes_per_s=400, now_s=0.0)  # 1 write/s/cell
    assert 900 <= t <= 1000


# ---------------------------------------------------------------------------
# ECC
# ---------------------------------------------------------------------------


def test_ecc_rber_grows_with_age():
    ages = [0.0, 0.25, 0.5, 1.0]
    rbers = [rber_at_age(MRM_RRAM, a * DAY, DAY) for a in ages]
    assert all(r2 > r1 for r1, r2 in zip(rbers, rbers[1:]))
    assert abs(rbers[-1] - 1e-4) / 1e-4 < 0.01  # RBER at retention ~ 1e-4


def test_ecc_large_blocks_amortize_parity():
    r = 1e-6
    small = design_code(512, r)
    big = design_code(8192, r)
    assert big.overhead < small.overhead


def test_ecc_max_safe_age_consistent():
    code = design_code(4096, rber_at_age(MRM_RRAM, DAY / 2, DAY))
    age = max_safe_age(MRM_RRAM, code, DAY)
    assert DAY / 4 < age < 2 * DAY


# ---------------------------------------------------------------------------
# Tiering
# ---------------------------------------------------------------------------


def _llama70b_classes():
    return [
        DataClassProfile("weights", 140e9, 6 * 800e9, 140e9 / (24 * HOUR),
                         24 * HOUR, False),
        DataClassProfile("kv_cache", 300e9, 2 * 800e9, 2.4e9, 600, True),
        DataClassProfile("activations", 10e9, 0.5e12, 0.5e12, 0.01, True,
                         random_access=True),
    ]


def test_placement_activations_avoid_mrm():
    """Write-heavy transient activations must land on HBM (paper §4:
    'MRM will co-exist with HBM for write-heavy data structures')."""
    tiers = [Tier(HBM3E, 192e9, count=8), Tier(MRM_RRAM, 768e9, count=16),
             Tier(LPDDR5X, 512e9, count=4)]
    res = solve_placement(_llama70b_classes(), tiers)
    assert res.feasible, res.violations
    assert res.assignment["activations"] == "hbm3e"
    assert res.assignment["weights"] == "mrm_rram"
    assert res.assignment["kv_cache"] == "mrm_rram"


def test_placement_detects_endurance_violation():
    # long-lived (no DCM endurance gain) + write-hot on a small region
    classes = [DataClassProfile("kv_cache", 1e9, 1e9, 300e9, 2 * DAY, True)]
    tiers = [Tier(MRM_RRAM, 2e9, count=10)]  # bw is ample; endurance is not
    res = evaluate_placement(classes, tiers, {"kv_cache": "mrm_rram"})
    assert not res.feasible
    assert any("endurance" in v for v in res.violations)


def test_placement_mrm_beats_hbm_only_on_energy():
    classes = _llama70b_classes()
    hbm_only = [Tier(HBM3E, 640e9, count=16)]
    mixed = [Tier(HBM3E, 192e9, count=8), Tier(MRM_RRAM, 768e9, count=16)]
    r_hbm = solve_placement(classes, hbm_only)
    r_mix = solve_placement(classes, mixed)
    assert r_hbm.feasible and r_mix.feasible
    assert r_mix.energy_w < r_hbm.energy_w


# ---------------------------------------------------------------------------
# Refresh scheduling
# ---------------------------------------------------------------------------


def test_refresh_live_data_rearmed():
    tr = RetentionTracker(margin=2.0)
    sched = RefreshScheduler(tr)
    rid = tr.track("weights", "mrm", 10, 1e6, now=0.0, retention_s=100.0)
    acts = sched.tick(49.0)
    assert acts == []
    acts = sched.tick(51.0)
    assert len(acts) == 1 and acts[0].action == Action.REFRESH
    r = tr.regions()[0]
    assert r.deadline > 100.0  # re-armed


def test_refresh_idle_data_migrates():
    tr = RetentionTracker(margin=2.0, idle_migrate_after_s=10.0)
    sched = RefreshScheduler(tr)
    rid = tr.track("session:1", "mrm", 1, 1e6, now=0.0, retention_s=100.0)
    tr.mark_idle(rid, 5.0)
    acts = sched.tick(51.0)
    assert len(acts) == 1 and acts[0].action == Action.MIGRATE
    assert tr.regions() == []


def test_released_regions_never_refresh():
    tr = RetentionTracker(margin=2.0)
    sched = RefreshScheduler(tr)
    rid = tr.track("session:1", "mrm", 1, 1e6, now=0.0, retention_s=100.0)
    tr.release(rid)
    assert sched.tick(1000.0) == []


@given(st.lists(st.floats(min_value=1.0, max_value=500.0), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_refresh_never_misses_deadline(lifetimes):
    """Property: every live region is serviced before its retention expires."""
    tr = RetentionTracker(margin=2.0)
    sched = RefreshScheduler(tr)
    for i, lt in enumerate(lifetimes):
        op = plan_write(MRM_RRAM, lt)
        tr.track(f"r{i}", "mrm", 1, 1.0, now=0.0, retention_s=op.retention_s)
    t = 0.0
    for _ in range(200):
        t += 7.0
        sched.tick(t)
        for r in tr.regions():
            age = t - r.written_at
            assert age <= r.retention_s + 1e-6, "retention deadline missed"


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------


def test_simulator_accounting_and_wear():
    ms = MemorySystem({"mrm": (MRM_RRAM, 1 << 26)})
    rid = ms.write_region("mrm", "w", 1 << 20, expected_lifetime_s=1e9)
    for _ in range(50):
        ms.read_region(rid)
    rep = ms.report()["tiers"]["mrm"]
    assert rep["read_gb"] > rep["write_gb"] * 40
    assert rep["seq_fraction"] == 1.0
    assert rep["wear_max"] >= 1.0
    ms.release_region(rid)
    rid2 = ms.write_region("mrm", "w2", 1 << 20, expected_lifetime_s=1e9)
    assert rid2 is not None


def test_simulator_refresh_charges_energy_and_wear():
    ms = MemorySystem({"mrm": (MRM_RRAM, 1 << 26)})
    rid = ms.write_region("mrm", "s", 1 << 20, expected_lifetime_s=30.0)
    e0 = ms.devices["mrm"].energy_j
    ms.advance(35.0)
    rep = ms.report()
    assert rep["refresh_stats"]["refresh"] >= 1
    assert ms.devices["mrm"].energy_j > e0
