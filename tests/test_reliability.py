"""Reliability plane (DESIGN.md §11): ECC numerics in isolation —
RBER-vs-age model, binomial tail bound, code selection — plus margin
derates, split-codeword selection, fault-injection determinism, the
simulator's ECC/scrub metering invariants, and the engine injection
path."""
import math

import numpy as np
import pytest

from repro.core import (FaultInjector, MemorySystem, SplitCode, TierEcc,
                        cell_cost_factor, data_class_of, derated_rber_at_age,
                        design_code, design_split_code, margin_derate,
                        rber_at_age, uncorrectable_log10)
from repro.core.ecc import (ECC_PROFILES, MARGIN_RBER_CAP,
                            STATE_RETENTION_FRAC, _log_binom_tail)
from repro.core.faults import CRIT_BIT_RANGE, flip_bits
from repro.core.memclass import DAY, HBM3E, MRM_MRAM, MRM_PCM, MRM_RRAM

MANAGED = [MRM_PCM, MRM_RRAM, MRM_MRAM]


# ---------------------------------------------------------------------------
# rber_at_age: monotonicity and clamps
# ---------------------------------------------------------------------------


def test_rber_monotone_in_age():
    ages = [0.0, DAY / 8, DAY / 4, DAY / 2, DAY, 2 * DAY]
    rbers = [rber_at_age(MRM_RRAM, a, DAY) for a in ages]
    assert all(b > a for a, b in zip(rbers, rbers[1:]))


def test_rber_anchors():
    # at write: rber0; at the programmed deadline: rber_at_retention
    assert rber_at_age(MRM_RRAM, 0.0, DAY) == pytest.approx(1e-9)
    assert rber_at_age(MRM_RRAM, DAY, DAY) == pytest.approx(1e-4)
    assert rber_at_age(MRM_RRAM, 0.0, DAY, rber0=1e-7) == pytest.approx(1e-7)


def test_rber_clamps():
    # age/retention saturates at 4x, and the rate itself at the 0.5 ceiling
    assert rber_at_age(MRM_RRAM, 4 * DAY, DAY) == \
        rber_at_age(MRM_RRAM, 400 * DAY, DAY)
    assert rber_at_age(MRM_RRAM, 400 * DAY, DAY,
                       rber_at_retention=1e-1) == 0.5
    # negative age is treated as fresh, zero retention does not divide
    assert rber_at_age(MRM_RRAM, -5.0, DAY) == pytest.approx(1e-9)
    assert rber_at_age(MRM_RRAM, 1.0, 0.0) <= 0.5


# ---------------------------------------------------------------------------
# _log_binom_tail vs exact binomial sums (small n)
# ---------------------------------------------------------------------------


def _exact_log_tail(n: int, t: int, p: float) -> float:
    s = sum(math.comb(n, k) * p ** k * (1 - p) ** (n - k)
            for k in range(t + 1, n + 1))
    return math.log10(s) if s > 0 else -300.0


@pytest.mark.parametrize("n", [10, 20, 30])
@pytest.mark.parametrize("t", [1, 2, 3, 5])
@pytest.mark.parametrize("p", [1e-4, 1e-3, 1e-2])
def test_log_binom_tail_vs_exact(n, t, p):
    if t < n * p:
        return  # below-mode regime covered by test_log_binom_tail_mode_guard
    approx = _log_binom_tail(n, t, p)
    exact = _exact_log_tail(n, t, p)
    # the dominant term is a lower bound of the tail, and within a tenth
    # of a decade of exact in every regime design_code operates in
    assert approx <= exact + 1e-12
    assert exact - approx < 0.1


def test_log_binom_tail_mode_guard():
    # t below the mode (n*p): the mass sits far above t, so the tail is
    # ~certain — without the guard the dominant term at t+1 underestimates
    # it catastrophically and design_code would return t=1 codes at RBERs
    # where every block fails
    assert _log_binom_tail(10_000, 5, 0.01) == math.log10(0.5)
    assert _log_binom_tail(100, 0, 0.5) == 0.0     # certain-failure regime
    assert _log_binom_tail(100, 3, 0.0) == -300.0  # no errors possible


# ---------------------------------------------------------------------------
# design_code boundary cases
# ---------------------------------------------------------------------------


def test_design_code_t_grows_with_rber():
    ts = [design_code(4096, r).correctable for r in (1e-7, 1e-5, 1e-4, 1e-3)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert ts[-1] > ts[0]


def test_design_code_infeasible_rber_raises():
    with pytest.raises(ValueError):
        design_code(4096, 0.5)


def test_design_code_stricter_target_costs_more():
    loose = design_code(4096, 1e-4, uber_target=1e-9)
    strict = design_code(4096, 1e-4, uber_target=1e-21)
    assert strict.correctable > loose.correctable
    assert strict.overhead > loose.overhead


# ---------------------------------------------------------------------------
# margin derate / cell cost — the density lever's two sides
# ---------------------------------------------------------------------------


def test_margin_derate_identity_at_nominal_and_growth_below():
    assert margin_derate(MRM_RRAM, MRM_RRAM.retention_s) == pytest.approx(1.0)
    d600 = margin_derate(MRM_RRAM, 600.0)
    d75 = margin_derate(MRM_RRAM, 75.0)
    assert 1.0 < d600 < d75
    # sub-second retentions clamp to 1 s instead of diverging
    assert margin_derate(MRM_RRAM, 1e-6) == margin_derate(MRM_RRAM, 1.0)


def test_derated_rber_capped_and_bounded():
    # the derate multiplies the anchors but never past the designable cap
    r = derated_rber_at_age(MRM_RRAM, 300.0, 600.0)
    assert 0.0 < r <= MARGIN_RBER_CAP
    assert derated_rber_at_age(MRM_RRAM, 100 * DAY, 600.0) <= 0.5


def test_cell_cost_factor_discount():
    assert cell_cost_factor(MRM_RRAM, MRM_RRAM.retention_s) == pytest.approx(1.0)
    c = cell_cost_factor(MRM_RRAM, 600.0)
    assert 0.65 <= c < 1.0
    assert cell_cost_factor(MRM_RRAM, 1.0) == 0.65  # floor


# ---------------------------------------------------------------------------
# split codeword: exponent-protected / mantissa-relaxed
# ---------------------------------------------------------------------------


def test_split_code_structure():
    sc = design_split_code(4096, 1e-4)
    assert isinstance(sc, SplitCode)
    assert sc.data_bits == 4096 * 8
    assert sc.parity_bits == sc.crit.parity_bits + sc.bulk.parity_bits
    assert sc.n_bits == sc.data_bits + sc.parity_bits
    assert sc.correctable == sc.crit.correctable
    assert sc.bulk.correctable == 1


def test_split_code_beats_uniform_at_derated_rber():
    rber = 1e-4  # where the density lever operates
    assert design_split_code(4096, rber).overhead < \
        design_code(4096, rber).overhead


def test_split_code_crossover_at_low_rber():
    # at nominal-margin RBER both designs carry the minimum t; the split
    # code pays its extra fixed bulk code, so TierEcc must prefer uniform
    rber = 1e-7
    assert design_split_code(4096, rber).overhead >= \
        design_code(4096, rber).overhead


def test_uncorrectable_log10_matches_tail():
    code = design_code(4096, 1e-5)
    assert uncorrectable_log10(code, 1e-5) == \
        _log_binom_tail(code.n_bits, code.correctable, 1e-5)
    assert uncorrectable_log10(code, 1e-5) < -15 < \
        uncorrectable_log10(code, 0.4)


# ---------------------------------------------------------------------------
# TierEcc code selection
# ---------------------------------------------------------------------------


def test_tier_ecc_off_meters_nothing():
    ecc = TierEcc(MRM_RRAM, "off")
    assert ecc.code_for("kv", 600.0) is None
    assert ecc.overhead_for("kv", 600.0) == 0.0
    assert ecc.summary() == {"profile": "off"}


def test_tier_ecc_rejects_unknown_profile():
    with pytest.raises(ValueError):
        TierEcc(MRM_RRAM, "strong")
    assert set(ECC_PROFILES) == {"off", "uniform", "domain"}


def test_tier_ecc_weights_always_uniform_strict():
    ecc = TierEcc(MRM_RRAM, "domain")
    for frac in STATE_RETENTION_FRAC.values():
        code = ecc.code_for("weights", MRM_RRAM.retention_s * frac)
        assert not isinstance(code, SplitCode)


def test_tier_ecc_domain_never_worse_and_wins_when_derated():
    dom = TierEcc(MRM_RRAM, "domain")
    uni = TierEcc(MRM_RRAM, "uniform")
    for state, frac in STATE_RETENTION_FRAC.items():
        r = MRM_RRAM.retention_s * frac
        od, ou = dom.overhead_for("kv", r), uni.overhead_for("kv", r)
        assert 0.0 < od <= ou
        if state != "hot":  # the density gate: derated states must shrink
            assert od < ou
    # shorter retention -> leakier cells -> more parity, both profiles
    for ecc in (dom, uni):
        ovs = [ecc.overhead_for("kv", MRM_RRAM.retention_s * f)
               for f in STATE_RETENTION_FRAC.values()]
        assert all(b >= a for a, b in zip(ovs, ovs[1:]))


def test_tier_ecc_cache_buckets():
    ecc = TierEcc(MRM_RRAM, "domain")
    # same eighth-decade bucket -> the designed code object is reused
    assert ecc.code_for("kv", 600.0) is ecc.code_for("kv", 601.0)


def test_tier_ecc_volatile_tier_does_not_crash():
    # HBM's sub-second retention clamps to the 1 s floor: a finite code
    # (its 32-byte blocks amortize parity poorly), not a crash, when a
    # volatile tier is configured with ECC on
    ecc = TierEcc(HBM3E, "domain")
    assert 0.0 < ecc.overhead_for("kv", HBM3E.retention_s) < 0.5


def test_data_class_of_owner_names():
    assert data_class_of("weights:llama") == "weights"
    assert data_class_of("kv:req-1") == "kv"
    assert data_class_of("prefix:hot") == "kv"


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


def test_flip_bits_deterministic_and_band_limited():
    rng1, rng2 = np.random.default_rng(7), np.random.default_rng(7)
    arr = np.zeros(256, np.float32)
    a = flip_bits(arr, 5, 9, rng1)
    b = flip_bits(arr, 5, 9, rng2)
    assert np.array_equal(a.view(np.uint32), b.view(np.uint32))
    assert a.shape == arr.shape and a.dtype == arr.dtype
    # crit flips stay in the sign/exponent band, bulk in the mantissa band
    lo, _ = CRIT_BIT_RANGE["float32"]
    crit_only = flip_bits(arr, 8, 0, np.random.default_rng(1))
    assert not np.any(crit_only.view(np.uint32) & ((1 << lo) - 1))
    bulk_only = flip_bits(arr, 0, 8, np.random.default_rng(2))
    assert not np.any(bulk_only.view(np.uint32) >> lo)


def test_flip_bits_zero_flips_is_identity():
    arr = np.arange(16, dtype=np.float32)
    assert flip_bits(arr, 0, 0, np.random.default_rng(0)) is arr


def _mem_with_region(ecc_profile="domain", **kw):
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 30)},
                       ecc_profile=ecc_profile, **kw)
    rid = mem.write_region("mrm", "kv:test", 1 << 20,
                           expected_lifetime_s=600.0)
    return mem, rid


def test_injector_rber_tracks_age():
    mem, rid = _mem_with_region()
    inj = FaultInjector(mem, 1e-3, seed=0)
    region = mem.region(rid)
    fresh = inj.page_rber(region)
    mem.now += 0.9 * region.retention_s
    aged = inj.page_rber(region)
    assert 0.0 < fresh < aged <= 0.5
    assert aged == pytest.approx(
        rber_at_age(MRM_RRAM, mem.now - region.written_at,
                    region.retention_s, rber0=1e-8, rber_at_retention=1e-3))


def test_injector_scrub_threshold():
    mem, rid = _mem_with_region()
    inj = FaultInjector(mem, 1e-3, seed=0)
    region = mem.region(rid)
    interval = region.retention_s / mem.tracker.margin
    mem.now = region.written_at + 0.5 * interval
    assert not inj.wants_scrub(region)
    mem.now = region.written_at + 0.8 * interval
    assert inj.wants_scrub(region)


def test_injector_fresh_protected_page_is_clean():
    mem, rid = _mem_with_region()
    inj = FaultInjector(mem, 1e-3, seed=0)
    arr = np.ones((64, 64), np.float32)
    out, n_bad = inj.corrupt(arr, mem.region(rid), protected=True)
    assert out is None and n_bad == 0
    assert inj.stats.uncorrectable_blocks == 0


def test_injector_overaged_page_corrupts_past_protection():
    mem, rid = _mem_with_region()
    inj = FaultInjector(mem, 1e-3, seed=0)
    region = mem.region(rid)
    mem.now = region.written_at + 10 * region.retention_s  # RBER clamps to 0.5
    arr = np.ones((64, 64), np.float32)
    out, n_bad = inj.corrupt(arr, region, protected=True)
    assert out is not None and n_bad > 0
    assert not np.array_equal(out, arr)
    assert inj.stats.crit_flips > 0 and inj.stats.uncorrectable_blocks > 0


def test_injector_unprotected_flips_land_directly():
    mem, rid = _mem_with_region(ecc_profile="off")
    inj = FaultInjector(mem, 1e-2, seed=3)
    region = mem.region(rid)
    mem.now = region.written_at + region.retention_s
    arr = np.zeros((64, 64), np.float32)
    out, n_bad = inj.corrupt(arr, region, protected=False)
    assert out is not None and n_bad == 0  # no accounting-scale sampling
    assert np.any(out != 0.0)


def test_injector_skips_unfloatable_dtypes():
    mem, rid = _mem_with_region()
    inj = FaultInjector(mem, 1e-3, seed=0)
    out, n_bad = inj.corrupt(np.zeros(8, np.int8), mem.region(rid), False)
    assert out is None and n_bad == 0


# ---------------------------------------------------------------------------
# simulator metering invariants
# ---------------------------------------------------------------------------


def test_ecc_off_is_byte_identical():
    mem, rid = _mem_with_region(ecc_profile="off")
    mem.read_region(rid, 1 << 20)
    d = mem.devices["mrm"]
    assert d.stats.ecc_read_bytes == d.stats.ecc_write_bytes == 0
    assert d.stats.scrub_read_bytes == 0 and d.stats.n_scrubs == 0


def test_ecc_bytes_metered_separately():
    """The ECC-bytes-balance invariant: check bits never pollute
    read_bytes/write_bytes (the §10 smoke identity survives), but do
    enter snapshot()/step-latency totals."""
    mem, rid = _mem_with_region(ecc_profile="domain")
    base = mem.devices["mrm"].stats.write_bytes
    mem.read_region(rid, 1 << 20)
    d = mem.devices["mrm"]
    ov = d.ecc.overhead_for("kv", mem.region(rid).retention_s)
    assert d.stats.ecc_write_bytes == pytest.approx((1 << 20) * ov)
    assert d.stats.ecc_read_bytes == pytest.approx((1 << 20) * ov)
    assert d.stats.write_bytes == base  # data counters untouched by ECC
    assert d.stats.read_bytes == 1 << 20
    reads, writes = mem.snapshot()["mrm"]
    assert reads == d.stats.read_bytes + d.stats.ecc_read_bytes + \
        d.stats.scrub_read_bytes
    assert writes == d.stats.write_bytes + d.stats.refresh_bytes + \
        d.stats.ecc_write_bytes


def test_ecc_capacity_ledger_tenant():
    mem, _ = _mem_with_region(ecc_profile="domain")
    d = mem.devices["mrm"]
    n = 10 << 20
    assert d.blocks_for_stored(n, "kv", 600.0) > d.blocks_for(n)
    # weights pay the strict uniform code's (larger) overhead
    assert d.blocks_for_stored(n, "weights", 600.0) >= \
        d.blocks_for_stored(n, "kv", 600.0)


def test_scrub_charged_as_refresh():
    mem, rid = _mem_with_region(ecc_profile="domain")
    d = mem.devices["mrm"]
    region = mem.region(rid)
    mem.advance(0.8 * region.retention_s / mem.tracker.margin)
    wear_before = d.wear.scrub_rewrites
    assert mem.scrub_region(rid)
    ov = d.ecc.overhead_for("kv", region.retention_s)
    assert d.stats.n_scrubs == 1
    assert d.stats.scrub_read_bytes == pytest.approx((1 << 20) * (1 + ov))
    assert d.stats.refresh_bytes >= 1 << 20       # rewrite charged as refresh
    assert d.wear.scrub_rewrites > wear_before    # in-place wear recorded
    assert region.written_at == mem.now           # retention clock re-armed
    assert not mem.scrub_region(10 ** 9)          # unknown region: no-op


def test_service_refresh_disabled_pages_age_out():
    mem, rid = _mem_with_region(ecc_profile="domain", service_refresh=False)
    region = mem.region(rid)
    written = region.written_at
    assert mem.advance(4 * region.retention_s) == []
    assert region.written_at == written           # never refreshed
    assert mem.devices["mrm"].stats.refresh_bytes == 0


# ---------------------------------------------------------------------------
# engine injection path
# ---------------------------------------------------------------------------


def test_engine_reports_reliability_and_injects():
    import jax

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import EngineConfig, ServeEngine

    full = get_config("gemma-2b")
    cfg = reduced(full)
    params = init_params(cfg, jax.random.key(0))
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 30), "hbm": (HBM3E, 1 << 28)},
                       ecc_profile="domain")
    eng = ServeEngine(
        cfg, params, mem,
        EngineConfig(max_slots=1, max_cache_len=64, weight_tier="hbm",
                     kv_tier="mrm", eos_token=-1, page_tokens=16,
                     chunk_tokens=16, paged_kernel=True,
                     inject_rber=1e-3, inject_seed=0),
        account_cfg=full)
    rng = np.random.default_rng(0)
    eng.submit(list(rng.integers(2, cfg.vocab_size, 24)), max_new_tokens=4)
    rep = eng.run_until_idle()
    rel = rep["reliability"]
    assert rel["ecc_profile"] == "domain"
    assert rel["inject_rber"] == pytest.approx(1e-3)
    assert rel["injection"]["pages_visited"] > 0
    # fresh pages under protection: injection observed but nothing lands
    assert rel["injection"]["uncorrectable_blocks"] == 0
    mrm = rel["tiers"]["mrm"]
    assert mrm["ecc_write_bytes"] > 0 and mrm["ecc_read_bytes"] > 0
