"""Unified retention lifecycle (DESIGN.md §9): the promote / demote /
decay / arrival state machine, tested in isolation from the engine, plus
the manager-level guarantee that hot leaves are demoted (reprogram
metered) before eviction pressure may pop them."""
import pytest

from repro.configs import get_config
from repro.core.memclass import HBM3E, MRM_RRAM
from repro.core.simulator import MemorySystem
from repro.serving import PagedKVManager, RetentionLifecycle
from repro.serving.kv_cache import Page
from repro.serving.radix import RadixKVIndex, RadixNode


def _mem(gb=1):
    return MemorySystem({"mrm": (MRM_RRAM, gb << 30), "hbm": (HBM3E, gb << 30)})


def _lifecycle(mem, **kw):
    args = dict(tier="mrm", kv_bytes_token=1024.0, session_retention_s=60.0,
                hot_retention_s=3600.0, hot_threshold=2, cold_ttl_s=5.0,
                demote_on_pressure=True)
    args.update(kw)
    return RetentionLifecycle(mem, **args)


def _node_with_page(mem, tokens=16, lock_ref=0, now=0.0):
    rid = mem.write_region("mrm", "prefix", tokens * 1024.0,
                           expected_lifetime_s=60.0)
    page = Page(0, rid, tokens, sealed=True, refcount=1, tier="mrm")
    root = RadixNode((), [], None, now)
    node = RadixNode(tuple(range(tokens)), [page], root, now)
    node.lock_ref = lock_ref
    return node, page


def test_promote_demote_decay_ordering():
    """The full SHORT -> HOT -> SHORT -> gone walk, in order: promotion
    needs the hit threshold, demotion resets it (promotion must be
    re-earned), and only then does cold decay apply."""
    mem = _mem()
    lc = _lifecycle(mem)
    node, page = _node_with_page(mem)

    # SHORT: below threshold nothing happens
    node.hits = 1
    lc.observe_reuse(node)
    assert not node.hot and lc.stats.retention_promotions == 0

    # SHORT -> HOT: threshold crossed; reprogram metered as refresh
    node.hits = 2
    refresh0 = mem.devices["mrm"].stats.refresh_bytes
    lc.observe_reuse(node)
    assert node.hot
    assert lc.stats.retention_promotions == 1
    assert lc.stats.promoted_pages == 1
    assert mem.devices["mrm"].stats.refresh_bytes > refresh0
    # the region's retention deadline was actually re-armed
    assert mem.tracker.get(page.region_id) is not None

    # HOT -> SHORT: pressure demotion meters another reprogram and
    # resets the hits — the node must re-earn promotion
    refresh1 = mem.devices["mrm"].stats.refresh_bytes
    assert lc.demote(node)
    assert not node.hot and node.hits == 0
    assert lc.stats.retention_demotions == 1
    assert lc.stats.demoted_pages == 1
    assert mem.devices["mrm"].stats.refresh_bytes > refresh1
    node.hits = 1
    lc.observe_reuse(node)
    assert not node.hot            # a stale hit count cannot re-promote

    # SHORT -> gone: cold decay applies only after the TTL
    node.last_access = 0.0
    assert not lc.decay_due(node, now=4.0)
    assert lc.decay_due(node, now=6.0)


def test_no_demotion_of_pinned_nodes():
    """A live session's path (lock_ref > 0) is never demoted: retention
    cannot be shortened out from under a pinned prefix."""
    mem = _mem()
    lc = _lifecycle(mem)
    node, _ = _node_with_page(mem, lock_ref=1)
    lc.promote(node)
    assert node.hot
    assert not lc.demote(node)
    assert node.hot and lc.stats.retention_demotions == 0
    # unpinning makes it demotable
    node.lock_ref = 0
    assert lc.demote(node)


def test_demotion_disabled_and_non_hot_refused():
    mem = _mem()
    off = _lifecycle(mem, demote_on_pressure=False)
    node, _ = _node_with_page(mem)
    off.promote(node)
    assert not off.demote(node)    # feature off: promotion stays one-way
    on = _lifecycle(mem)
    node2, _ = _node_with_page(mem)
    assert not on.demote(node2)    # not hot: nothing to demote


def test_arrival_programming():
    """Migration arrival routes through the same machine: donor-hot
    prefixes land in the hot tier at long retention, cold ones at
    session retention in the base tier."""
    mem = _mem()
    lc = _lifecycle(mem, hot_tier="hbm")
    assert lc.arrival(hot=True) == ("hbm", 3600.0)
    assert lc.arrival(hot=False) == ("mrm", 60.0)
    assert lc.stats.arrivals_hot == 1 and lc.stats.arrivals_short == 1
    # without a hot tier, hot arrivals stay in the base tier (long
    # retention still re-programmed)
    lc2 = _lifecycle(mem)
    assert lc2.arrival(hot=True) == ("mrm", 3600.0)


def test_hot_leaves_demoted_before_eviction_reaches_them():
    """Manager-level acceptance: under sustained eviction pressure, cold
    leaves are evicted first, and a hot leaf passes through a metered
    demotion (HOT -> SHORT) before eviction may pop it."""
    cfg = get_config("qwen3-8b")
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 24), "hbm": (HBM3E, 1 << 30)})
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=4, policy="evict-lru",
                        hot_threshold=1, demote_on_pressure=True)
    # publish two prefixes; make one hot via observed reuse
    for sid, base in ((0, 100), (1, 500)):
        kv.open_session(sid)
        kv.append_tokens(sid, 8)
        kv.register_prefix(sid, list(range(base, base + 8)))
        kv.close_session(sid)
    hot_key = list(range(100, 108))
    kv.open_session(2, match=kv.match_prefix(hot_key))   # bumps hits -> hot
    kv.close_session(2)
    assert any(n.hot for n in kv.radix.nodes())
    refresh0 = mem.devices["mrm"].stats.refresh_bytes
    # drain the tree under explicit pressure: the cold leaf must go
    # before the hot one, and the hot one must be demoted first
    popped = kv.evict_prefixes()
    assert kv.lifecycle.stats.retention_demotions >= 1
    assert mem.devices["mrm"].stats.refresh_bytes > refresh0
    assert kv.radix.n_nodes() == 0          # eventually everything went
    # every progress step was either a real eviction or a demotion —
    # and the hot leaf took its demotion before its eviction
    assert popped == (kv.pressure.prefix_evictions
                      + kv.lifecycle.stats.retention_demotions)
    assert kv.pressure.prefix_evictions == 2


def test_sustained_pressure_orders_demote_before_evict():
    """End-to-end pressure path: a capacity-squeezed tier with a hot
    prefix resolves allocations by evicting cold leaves, then demoting
    the hot leaf (metered), then evicting it — never an unresolved
    event, ledger balanced."""
    cfg = get_config("qwen3-8b")
    mem = MemorySystem({"mrm": (MRM_RRAM, 1 << 22), "hbm": (HBM3E, 1 << 30)})
    kv = PagedKVManager(cfg, mem, "mrm", page_tokens=4, policy="evict-lru",
                        high_watermark=0.5,
                        hot_threshold=1, demote_on_pressure=True)
    kv.open_session(0)
    kv.append_tokens(0, 8)
    kv.register_prefix(0, list(range(8)))
    kv.close_session(0)
    kv.open_session(1, match=kv.match_prefix(list(range(8))))  # -> hot
    kv.close_session(1)
    assert any(n.hot for n in kv.radix.nodes())
    # a big session forces allocations past capacity
    kv.open_session(9)
    kv.append_tokens(9, 4 * 40)
    p = kv.pressure
    assert p.events > 0
    assert p.events == (p.resolved_evict + p.resolved_spill
                        + p.resolved_recompute + p.unresolved)
    assert p.unresolved == 0 and kv.dropped_allocs == 0
    assert kv.lifecycle.stats.retention_demotions >= 1
    # a demote-progress round is NOT an eviction: the watermark counter
    # stays a subset of real leaf evictions even when demotion engages
    assert p.watermark_evictions <= p.prefix_evictions
    kv.close_session(9)


def test_lifecycle_stats_surface_in_prefix_report():
    cfg = get_config("qwen3-8b")
    kv = PagedKVManager(cfg, _mem(8), "mrm", page_tokens=4)
    rep = kv.prefix_report()
    for key in ("retention_promotions", "retention_demotions",
                "demoted_pages", "cold_decays", "adopted_pages",
                "arrivals_hot", "tail_hits", "tail_tokens_copied",
                "tail_copy_bytes"):
        assert key in rep, key
    assert kv.radix_stats is kv.lifecycle.stats   # one ledger, one object
